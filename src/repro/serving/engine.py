"""Continuous-batching serving engine + work-stealing request frontend.

Two layers:

* ContinuousBatcher — the device side: a fixed pool of B decode slots over
  stacked KV caches.  Admitting a request runs a batch-1 prefill and splices
  its caches into the slot (dynamic_update_slice on the batch dim); every
  engine step decodes all live slots in one decode step — by default
  `decode_step_ws`, which schedules the slots' ragged attention (and, with
  `cfg.moe_dispatch == "ws"`, the expert FFN) as tile tasks on the
  fence-free work-stealing megakernel; `use_ws=False` falls back to the
  jitted dense decode_step.  On a multi-device host, `cfg.moe_dispatch ==
  "mesh-ws"` shards the expert FFN's queues over the mesh "model" axis
  instead (repro.mesh_ws, DESIGN.md §7) — serving is the mesh dispatch's
  primary consumer, since it is forward-only.  Finished slots free
  immediately and are refilled the same step (the vLLM-style
  iteration-level scheduling, in JAX).

* WorkStealingFrontend — the host side: per-engine-replica request queues
  implemented with the *literal* WS-WMULT algorithm (paper Fig. 7).  Each
  replica's scheduler thread Takes from its own queue and Steals from busy
  replicas when idle; weak multiplicity means a request may be admitted by
  two replicas under contention — admission is idempotent (same tokens) and
  the frontend deduplicates on completion, keeping whichever finished first.
  This is the paper's fence-free load balancing as a serving feature: no
  lock and no CAS anywhere on the request hot path.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EMPTY, WSWMult
from repro.models import (
    Caches,
    decode_step,
    decode_step_unified,
    decode_step_ws,
    init_caches,
    prefill,
    unified_step_supported,
    ws_decode_supported,
)
from repro.wstrace.metrics import SchedulerMetrics


def jit_decode_step_ws(cfg, *, schedule: str = "ws", bk: int = 64,
                       n_programs: int = 8):
    """Compiled end-to-end WS decode step: ``jit(decode_step_ws)`` with the
    config closed over (it carries static shape info) and ``(params,
    caches, tokens, pos)`` traced.

    Inside the trace the per-slot lengths are tracers, so every layer's
    attention queues — and, with ``cfg.moe_dispatch == "ws"``, the expert
    FFN queues — are built by the traced Put (fixed worst-case shapes, live
    masks) and drained by the same megakernel the eager path launches: the
    whole decode step, scheduler included, is one XLA computation.  One
    compilation per (slot count, capacity) shape, like the dense
    ``decode_step`` the batcher jits.
    """
    from repro.models import decode_step_ws as _ws

    return jax.jit(
        lambda p, c, t, pos: _ws(
            p, cfg, c, t, pos, schedule=schedule, bk=bk, n_programs=n_programs
        )
    )


@dataclass
class Request:
    rid: int
    tokens: np.ndarray  # [T] int32 prompt
    max_new: int = 16
    out: List[int] = field(default_factory=list)


class ContinuousBatcher:
    def __init__(
        self,
        params,
        cfg,
        *,
        slots: int,
        capacity: int,
        greedy: bool = True,
        temperature: float = 1.0,
        sample_seed: int = 0,
        attn_schedule: str = "ws",
        use_ws: bool = True,
        jit_ws: bool = False,
        unified_step: bool = False,
        step_deadline_s: Optional[float] = None,
        watchdog_cooldown: int = 1,
        fault_plan=None,
    ):
        self.params, self.cfg = params, cfg
        self.B, self.cap = slots, capacity
        self.caches = init_caches(cfg, slots, capacity)
        self.live: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, dtype=np.int32)  # next write slot per seq
        self.budget = np.zeros(slots, dtype=np.int32)
        self.greedy = greedy
        self.temperature = float(temperature)
        # seeded host-side sampler so greedy=False runs are reproducible
        self._rng = np.random.default_rng(sample_seed)
        # Decode attention schedule: with `use_ws` (the default, for the
        # architectures decode_step_ws covers) every engine step routes the
        # slots' ragged lengths through the repro.pallas_ws scheduler
        # ("ws" steals, "static" drains owner queues).  `jit_ws` compiles
        # that whole step — queues built by the traced Put on device —
        # instead of re-building queues host-side each iteration.
        # `use_ws=False` is the escape hatch back to the jitted dense
        # decode_step.
        if attn_schedule not in ("ws", "static"):
            raise ValueError(f"attn_schedule must be 'ws' or 'static': {attn_schedule!r}")
        self.attn_schedule = attn_schedule
        self.use_ws = bool(use_ws and ws_decode_supported(cfg))
        # Unified mode: ONE launch_ws_grid launch per engine step carries the
        # decode tiles, at most one admitted prompt's prefill tiles, and (MoE)
        # the expert tiles (models.unified, DESIGN.md §5).  admit() defers
        # the prefill into the next step instead of running it standalone;
        # the split-launch path below stays as the escape hatch and oracle.
        if unified_step and not unified_step_supported(cfg):
            raise ValueError(f"unified_step unsupported for config {cfg.name!r}")
        self.unified = bool(unified_step)
        self._pending = deque()          # (slot, Request) awaiting prefill
        self._pending_slots: set = set()
        if self.use_ws and jit_ws:
            self._decode = jit_decode_step_ws(cfg, schedule=attn_schedule)
        elif self.use_ws:
            self._decode = lambda p, c, t, pos: decode_step_ws(
                p, cfg, c, t, pos, schedule=attn_schedule
            )
        else:
            self._decode = jax.jit(
                lambda p, c, t, pos: decode_step(p, cfg, c, t, pos)
            )
        self._prefill = jax.jit(
            lambda p, b, cap=capacity: prefill(p, cfg, b, capacity=cap)
        )
        # per-step serving telemetry (latency percentiles, slot utilization,
        # admissions) — read it back via stats()
        self.metrics = SchedulerMetrics(slots=slots)
        # Watchdog (unified mode): a step whose logits come back non-finite
        # is discarded and redone on the split path this very step; a step
        # that blows `step_deadline_s` routes the next `watchdog_cooldown`
        # steps through the split path.  `fault_plan` (a
        # repro.chaos.EngineFaultPlan) injects poisoned logits / inflated
        # latencies at chosen steps so both trips are drillable.
        self.step_deadline_s = step_deadline_s
        self.watchdog_cooldown = int(watchdog_cooldown)
        self.fault_plan = fault_plan
        self.degradations: List[dict] = []
        self._step_idx = 0
        self._degraded_until = -1

    # -- sampling --------------------------------------------------------------
    def _select(self, logits) -> np.ndarray:
        """Next-token choice per row honoring the `greedy` flag: argmax, or
        seeded temperature sampling from softmax(logits / T)."""
        lg = np.asarray(logits, dtype=np.float32)
        if self.greedy:
            return lg.argmax(axis=-1)
        z = lg / max(self.temperature, 1e-6)
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.array(
            [self._rng.choice(p.shape[-1], p=row) for row in p], dtype=np.int64
        )

    # -- admission ------------------------------------------------------------
    def _splice_slot(self, slot: int, c1) -> None:
        """Splice batch-1 prefill caches into the slot's batch row."""

        def splice(full, one):
            if not hasattr(one, "ndim"):
                return full
            return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=1)

        self.caches = jax.tree_util.tree_map(splice, self.caches, c1)

    def admit(self, req: Request) -> bool:
        # a prompt of capacity-1 tokens is the longest the slot can hold:
        # the splice needs len(tokens) cache rows plus one for the first
        # generated token (admitting len >= capacity corrupts the splice)
        if not 0 < len(req.tokens) < self.cap:
            return False
        free = [
            i for i, r in enumerate(self.live)
            if r is None and i not in self._pending_slots
        ]
        if not free:
            return False
        slot = free[0]
        if self.unified:
            # defer the prefill into the next unified step — it rides the
            # same launch as that step's decode tiles
            self.live[slot] = req
            self._pending.append((slot, req))
            self._pending_slots.add(slot)
            self.pos[slot] = 0
            self.budget[slot] = req.max_new
            self.metrics.record_admission()
            return True
        batch = {"tokens": jnp.asarray(req.tokens, jnp.int32)[None, :]}
        logits, c1 = self._prefill(self.params, batch)
        self._splice_slot(slot, c1)
        first = int(self._select(np.asarray(logits[:1]))[0])
        req.out.append(first)
        self.live[slot] = req
        self.pos[slot] = len(req.tokens)
        self.budget[slot] = req.max_new - 1
        self.metrics.record_admission()
        return True

    # -- one engine iteration ---------------------------------------------------
    def step(self) -> List[Request]:
        if not any(r is not None for r in self.live):
            return []
        if self.unified:
            return self._step_unified()
        n_live = self.n_live
        t0 = time.perf_counter()
        tokens = np.zeros((self.B, 1), dtype=np.int32)
        for i, r in enumerate(self.live):
            if r is not None:
                tokens[i, 0] = r.out[-1]
        # per-slot decode positions (heterogeneous sequence lengths)
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens), jnp.asarray(self.pos)
        )
        done = []
        nxt = self._select(np.asarray(logits))  # syncs the device step
        self.metrics.record_step(time.perf_counter() - t0, n_live)
        for i, r in enumerate(self.live):
            if r is None:
                continue
            r.out.append(int(nxt[i]))
            self.pos[i] += 1
            self.budget[i] -= 1
            if self.budget[i] <= 0 or self.pos[i] >= self.cap - 1:
                done.append(r)
                self.live[i] = None
        if done:
            self.metrics.record_completion(len(done))
        return done

    def _degrade(self, step_idx: int, kind: str, detail: str) -> None:
        self.degradations.append(dict(step=step_idx, kind=kind, detail=detail))
        self.metrics.record_degradation(kind)

    def _step_unified(self) -> List[Request]:
        """One engine step = ONE mixed-mode megakernel launch: all live
        slots' decode tiles plus (at most) one pending admission's prefill
        tiles, stage-gated in a single `launch_ws_grid` grid.

        A per-step watchdog guards the launch: non-finite logits discard
        the unified result and redo the step on the split path (standalone
        prefill + per-step decode — graceful degradation, not a crash);
        blowing ``step_deadline_s`` routes the following
        ``watchdog_cooldown`` steps through the split path directly."""
        fold = self._pending.popleft() if self._pending else None
        n_live = self.n_live
        t0 = time.perf_counter()
        step_idx = self._step_idx
        self._step_idx += 1
        done = None
        if step_idx >= self._degraded_until:
            done = self._try_unified(fold, step_idx)
        if done is None:
            done = self._step_split_fallback(fold)
        elapsed = time.perf_counter() - t0
        observed = elapsed
        if self.fault_plan is not None and self.fault_plan.slows(step_idx):
            observed += self.fault_plan.added_latency_s
        if (self.step_deadline_s is not None
                and observed > self.step_deadline_s
                and step_idx >= self._degraded_until):
            self._degrade(step_idx, "deadline",
                          f"step took {observed:.4f}s > "
                          f"{self.step_deadline_s:.4f}s; next "
                          f"{self.watchdog_cooldown} step(s) on split path")
            self._degraded_until = step_idx + 1 + self.watchdog_cooldown
        self.metrics.record_step(elapsed, n_live)
        if done:
            self.metrics.record_completion(len(done))
        return done

    def _try_unified(self, fold, step_idx: int) -> Optional[List[Request]]:
        """The unified launch + bookkeeping; returns None (nothing
        committed — caches untouched, no token appended) when the watchdog
        rejects the launch's logits."""
        tokens = np.zeros((self.B, 1), dtype=np.int32)
        for i, r in enumerate(self.live):
            if r is not None and r.out:
                tokens[i, 0] = r.out[-1]
        ptok = (
            jnp.asarray(fold[1].tokens, jnp.int32)[None, :]
            if fold is not None else None
        )
        logits, caches, rep = decode_step_unified(
            self.params, self.cfg, self.caches, jnp.asarray(tokens), self.pos,
            prefill_tokens=ptok,
        )
        lg = np.asarray(logits)  # syncs the device step
        plg = np.asarray(rep.prefill_logits) if fold is not None else None
        if self.fault_plan is not None and self.fault_plan.poisons(step_idx):
            lg = np.full_like(lg, np.nan)
        if not np.isfinite(lg).all() or (
                plg is not None and not np.isfinite(plg).all()):
            self._degrade(step_idx, "non-finite",
                          "unified logits non-finite; redoing the step on "
                          "the split path")
            return None
        self.caches = caches
        done = []
        nxt = self._select(lg)
        folded_slot = -1
        if fold is not None:
            slot, req = fold
            self._pending_slots.discard(slot)
            folded_slot = slot
            self._splice_slot(slot, Caches(kv=rep.prefill_kv))
            first = int(self._select(plg)[0])
            req.out.append(first)
            self.pos[slot] = len(req.tokens)
            self.budget[slot] = req.max_new - 1
            if self.budget[slot] <= 0 or self.pos[slot] >= self.cap - 1:
                done.append(req)
                self.live[slot] = None
        for i, r in enumerate(self.live):
            # slots still awaiting their prefill fold (and the slot folded
            # this step) produced no decode token this launch
            if r is None or i in self._pending_slots or i == folded_slot:
                continue
            r.out.append(int(nxt[i]))
            self.pos[i] += 1
            self.budget[i] -= 1
            if self.budget[i] <= 0 or self.pos[i] >= self.cap - 1:
                done.append(r)
                self.live[i] = None
        return done

    def _step_split_fallback(self, fold) -> List[Request]:
        """Graceful degradation for one unified step: the same admission +
        decode work done as split launches (standalone prefill, per-step
        decode).  Greedy decode is deterministic, so the tokens this path
        produces are exactly what the healthy unified launch would have
        produced (PR 8's bitwise split/unified parity)."""
        done = []
        folded_slot = -1
        if fold is not None:
            slot, req = fold
            self._pending_slots.discard(slot)
            folded_slot = slot
            batch = {"tokens": jnp.asarray(req.tokens, jnp.int32)[None, :]}
            logits1, c1 = self._prefill(self.params, batch)
            self._splice_slot(slot, c1)
            first = int(self._select(np.asarray(logits1[:1]))[0])
            req.out.append(first)
            self.pos[slot] = len(req.tokens)
            self.budget[slot] = req.max_new - 1
            if self.budget[slot] <= 0 or self.pos[slot] >= self.cap - 1:
                done.append(req)
                self.live[slot] = None
        decodable = [
            i for i, r in enumerate(self.live)
            if r is not None and r.out
            and i not in self._pending_slots and i != folded_slot
        ]
        if decodable:
            tokens = np.zeros((self.B, 1), dtype=np.int32)
            for i in decodable:
                tokens[i, 0] = self.live[i].out[-1]
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(tokens),
                jnp.asarray(self.pos)
            )
            nxt = self._select(np.asarray(logits))
            for i in decodable:
                r = self.live[i]
                r.out.append(int(nxt[i]))
                self.pos[i] += 1
                self.budget[i] -= 1
                if self.budget[i] <= 0 or self.pos[i] >= self.cap - 1:
                    done.append(r)
                    self.live[i] = None
        return done

    def stats(self) -> dict:
        """Serving metrics snapshot: per-step latency p50/p99 (ms), mean
        slot utilization, admissions/completions (SchedulerMetrics)."""
        return self.metrics.snapshot()

    @property
    def n_live(self) -> int:
        return sum(r is not None for r in self.live)

    def live_lengths(self) -> np.ndarray:
        """Per-slot KV lengths (0 for free slots) — the ragged shape the
        ws attention path schedules over."""
        return np.where(
            np.array([r is not None for r in self.live]), self.pos, 0
        ).astype(np.int64)


def ragged_slot_attention(q, k_cache, v_cache, batcher_or_lengths, *, schedule=None, bk=64):
    """Decode attention over a continuous batcher's ragged slots.

    The engine's decode slots always hold wildly different sequence lengths
    (that is the whole point of continuous batching), so a static attention
    grid wastes tile-slots on short slots while the longest slot serializes.
    This hands the live lengths to the fence-free work-stealing scheduler.

    ``q``: [B, H, hd] one query row per slot; ``k_cache``/``v_cache``:
    [B, Hkv, S, hd] stacked caches; ``batcher_or_lengths``: a
    :class:`ContinuousBatcher` or an explicit [B] length vector.  When
    ``schedule`` is None it follows the batcher's ``attn_schedule``
    ("ws" for a bare length vector).
    """
    from repro.pallas_ws.ragged import ragged_decode_attention

    if isinstance(batcher_or_lengths, ContinuousBatcher):
        lengths = batcher_or_lengths.live_lengths()
        schedule = batcher_or_lengths.attn_schedule if schedule is None else schedule
    else:
        lengths = np.asarray(batcher_or_lengths)
        schedule = "ws" if schedule is None else schedule
    return ragged_decode_attention(
        q, k_cache, v_cache, lengths, schedule=schedule, bk=bk
    )


class WorkStealingFrontend:
    """N engine replicas fed by WS-WMULT queues; idle replicas steal."""

    def __init__(self, make_batcher, n_replicas: int = 2, steal: bool = True,
                 max_admission_retries: int = 8, crash_plan=None):
        self.queues = [WSWMult(storage="linked", node_len=32) for _ in range(n_replicas)]
        self.batchers = [make_batcher() for _ in range(n_replicas)]
        self.steal = steal
        self.completed: Dict[int, Request] = {}
        # requests a batcher refused for cause (e.g. prompt >= cache
        # capacity) — surfaced here instead of being silently dropped
        self.rejected: Dict[int, Request] = {}
        # aggregate counters plus the per-replica scheduling history the
        # run used to discard — read both back via stats()
        self.counters = {
            "admitted": 0, "stolen": 0, "dup_completed": 0, "rejected": 0,
            "gave_up": 0, "readmitted": 0, "crashed": 0,
        }
        # Transient admissions (no free slot at admit time) back off
        # exponentially instead of hot-spinning the queue: retry n waits
        # 2^min(n,6) iterations, and after `max_admission_retries` the
        # request is surfaced in `rejected` (+ the "gave_up" counter)
        # rather than spinning run() to max_iters with zero progress.
        self.max_admission_retries = int(max_admission_retries)
        self._iter = 0
        self._backoff: List[List] = [[] for _ in range(n_replicas)]
        self._retries: Dict[int, int] = {}
        # Crash injection + idempotent re-admission (repro.chaos
        # ReplicaCrashPlan): `_orig[rid]` remembers each request's original
        # prompt/budget so a resumed copy (prompt ++ tokens-so-far,
        # remaining budget) can be reassembled into the full stream on
        # completion — no token is ever emitted twice, and greedy decode
        # makes the resumed stream identical to an uninterrupted one.
        self.crash_plan = crash_plan
        self.dead: set = set()
        self._orig: Dict[int, tuple] = {}
        self.per_replica = [
            {"submitted": 0, "admitted": 0, "stolen": 0, "completed": 0,
             "rejected": 0}
            for _ in range(n_replicas)
        ]
        # Per-replica rotating victim cursor: scanning victims from a fixed
        # origin (always replica 0 first) starves high-index replicas under
        # contention — every thief drains the low queues before ever looking
        # at the high ones.  Each successful or failed scan advances the
        # cursor so steal pressure spreads over all victims.
        self._victim_rr = [0] * n_replicas
        self._lock = threading.Lock()

    def submit(self, replica: int, req: Request):
        self._orig.setdefault(req.rid, (np.asarray(req.tokens), req.max_new))
        self.per_replica[replica]["submitted"] += 1
        self.queues[replica].put(req)

    def _reassemble(self, r: Request) -> Request:
        """Fold a resumed request's pre-crash emission back in: a resume
        copy carries prompt = original ++ already-emitted, so the full
        stream is that suffix plus this epoch's output."""
        orig = self._orig.get(r.rid)
        if orig is None:
            return r
        toks, max_new = orig
        if len(r.tokens) > len(toks):
            prev = [int(t) for t in np.asarray(r.tokens)[len(toks):]]
            return Request(r.rid, toks, max_new, prev + list(r.out))
        return r

    def _crash(self, rep: int) -> None:
        """Kill replica `rep`: its engine (slots, caches, pending folds) is
        lost, its *queue* survives — queued-but-unadmitted requests stay
        stealable by the living replicas, which is the paper's whole
        point.  In-flight requests are re-admitted idempotently to
        survivors keyed by rid + tokens-generated-so-far."""
        b = self.batchers[rep]
        self.dead.add(rep)
        self.counters["crashed"] += 1
        survivors = [i for i in range(len(self.batchers))
                     if i not in self.dead]
        inflight, seen = [], set()
        for r in list(b.live):
            # unified-mode pending folds appear in b.live too, so this
            # sweep covers deferred admissions; dedup by object identity
            if r is not None and id(r) not in seen:
                seen.add(id(r))
                inflight.append(r)
        k = 0
        for r in inflight:
            rid = r.rid
            with self._lock:
                if rid in self.completed:
                    continue
            full = self._reassemble(r)
            emitted = list(full.out)
            toks, max_new = self._orig.get(
                rid, (np.asarray(r.tokens), r.max_new))
            remaining = max_new - len(emitted)
            if remaining <= 0:
                # the crash landed exactly on the completion boundary:
                # everything was already emitted — complete, don't resume
                with self._lock:
                    if rid in self.completed:
                        self.counters["dup_completed"] += 1
                    else:
                        self.completed[rid] = Request(
                            rid, toks, max_new, emitted)
                continue
            resume_tokens = np.concatenate([
                np.asarray(toks),
                np.asarray(emitted, dtype=np.asarray(toks).dtype),
            ]) if emitted else np.asarray(toks)
            resume = Request(rid, resume_tokens, remaining)
            tgt = survivors[k % len(survivors)] if survivors else rep
            k += 1
            self.counters["readmitted"] += 1
            self.per_replica[tgt]["submitted"] += 1
            self.queues[tgt].put(resume)

    def _next_request(self, replica: int) -> Optional[Request]:
        req = self.queues[replica].take()
        if req is not EMPTY:
            return req
        if self.steal and len(self.queues) > 1:
            victims = [v for v in range(len(self.queues)) if v != replica]
            start = self._victim_rr[replica] % len(victims)
            for j in range(len(victims)):
                v = victims[(start + j) % len(victims)]
                got = self.queues[v].steal(pid=1 + replica)
                if got is not EMPTY:
                    # resume past this victim next time
                    self._victim_rr[replica] = (start + j + 1) % len(victims)
                    self.counters["stolen"] += 1
                    self.per_replica[replica]["stolen"] += 1
                    return got
            self._victim_rr[replica] = (start + 1) % len(victims)
        return None

    def run_iteration(self) -> bool:
        """One round-robin pass over the replicas: fill free slots from the
        queues (honoring each admit's verdict), then step every busy
        batcher.  Returns True if anything happened — an admission, a
        rejection, or a live engine step."""
        worked = False
        it = self._iter
        self._iter += 1
        if self.crash_plan is not None:
            for rep in self.crash_plan.due(it):
                if rep not in self.dead and rep < len(self.batchers):
                    self._crash(rep)
                    worked = True
        # release backed-off transients whose retry timer expired
        for rep, parked in enumerate(self._backoff):
            if parked:
                due = [e for e in parked if e[0] <= it]
                if due:
                    self._backoff[rep] = [e for e in parked if e[0] > it]
                    for _, req in due:
                        self.queues[rep].put(req)
        for rep, b in enumerate(self.batchers):
            if rep in self.dead:
                continue
            while b.n_live < b.B:
                req = self._next_request(rep)
                if req is None:
                    break
                # idempotent admission: a stolen duplicate re-runs prefill
                ok = b.admit(Request(req.rid, req.tokens, req.max_new))
                if not ok:
                    cap = getattr(b, "cap", None)
                    if cap is not None and not 0 < len(req.tokens) < cap:
                        # permanent: the prompt can never fit this engine's
                        # cache — surface it, don't retry
                        with self._lock:
                            if req.rid not in self.rejected:
                                self.rejected[req.rid] = req
                        self.counters["rejected"] += 1
                        self.per_replica[rep]["rejected"] += 1
                        worked = True
                        continue
                    # transient (no free slot despite the n_live check,
                    # e.g. a racing admission): bounded exponential backoff,
                    # then give up visibly — requeueing unconditionally
                    # could spin run() to max_iters with zero progress
                    n = self._retries.get(req.rid, 0) + 1
                    self._retries[req.rid] = n
                    if n > self.max_admission_retries:
                        with self._lock:
                            if req.rid not in self.rejected:
                                self.rejected[req.rid] = req
                        self.counters["rejected"] += 1
                        self.counters["gave_up"] += 1
                        self.per_replica[rep]["rejected"] += 1
                        worked = True
                        continue
                    self._backoff[rep].append((it + (1 << min(n, 6)), req))
                    break
                self.counters["admitted"] += 1
                self.per_replica[rep]["admitted"] += 1
                worked = True
            if b.n_live:
                for r in b.step():
                    self.per_replica[rep]["completed"] += 1
                    r = self._reassemble(r)
                    with self._lock:
                        if r.rid in self.completed:
                            self.counters["dup_completed"] += 1  # weak mult.
                        else:
                            self.completed[r.rid] = r
                worked = True
        # parked transients keep the loop alive until they retry or give up
        if any(self._backoff):
            worked = True
        return worked

    def run(self, max_iters: int = 10_000) -> Dict[int, Request]:
        """Drive all replicas round-robin until queues drain and slots empty."""
        for _ in range(max_iters):
            # an iteration with no admission and no live slot means every
            # queue answered EMPTY to take AND steal: fully drained.
            if not self.run_iteration():
                break
        return self.completed

    def stats(self) -> dict:
        """Scheduling history of the run: aggregate counters, per-replica
        submit/admit/steal/completion counts, and each batcher's
        SchedulerMetrics snapshot (when the batcher exposes one)."""
        out = {
            "totals": dict(self.counters),
            "per_replica": [dict(c) for c in self.per_replica],
        }
        snaps = []
        for b in self.batchers:
            snap = getattr(b, "stats", None)
            snaps.append(snap() if callable(snap) else None)
        out["batchers"] = snaps
        return out

"""jax version compatibility for the Pallas kernels.

jax renamed ``pltpu.TPUCompilerParams`` (<= 0.5.x) to
``pltpu.CompilerParams``; accept both so the kernels run on the container's
jax as well as current releases.
"""

from jax.experimental.pallas import tpu as _pltpu

compiler_params = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams

"""Flash attention forward as a Pallas TPU kernel.

TPU adaptation of the paper-adjacent hot spot (see DESIGN.md §5): online-
softmax tiling sized for VMEM, MXU-aligned blocks (bq/bk/hd multiples of 128
on real hardware; tests sweep smaller shapes in interpret mode).

Grid: (B, H, nq, nk) with nk innermost and *sequentially* iterated, so the
running max / sum / accumulator live in VMEM scratch across the k sweep of
one (b, h, qi) cell.  GQA is handled in the BlockSpec index_map: query head
h reads kv head h // (H // Hkv) — no materialized head expansion.

Causal / sliding-window masking is applied inside the block; fully-masked
(q, k) block pairs are skipped with pl.when (the compute-roofline win of
causal flash: ~2x at long S).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import compiler_params as _compiler_params

NEG_INF = -1e30


def _fwd_kernel(
    q_ref, k_ref, v_ref,  # inputs
    o_ref, lse_ref,  # outputs
    m_scr, l_scr, acc_scr,  # scratch
    *, scale: float, causal: bool, window: int, bq: int, bk: int, nk: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # block-level skip: a (q, k) block pair is live unless fully masked
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1  # newest q sees oldest k
    if window > 0:
        live &= q_start - (k_start + bk - 1) < window  # oldest q in window of newest k

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[...] + jnp.log(l)


def flash_attention_fwd(
    q, k, v, *, causal: bool = True, window: int = 0,
    bq: int = 128, bk: int = 128, interpret: bool = False,
):
    """q: [B, H, S, hd]; k, v: [B, Hkv, S, hd] -> (out, lse [B, H, S])."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    nq, nk = S // bq, S // bk
    assert nq * bq == S and nk * bk == S, (S, bq, bk)
    scale = hd**-0.5

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out, lse

"""jit'd wrapper for the flash attention kernel, with custom VJP.

Forward: the Pallas kernel (TPU target; `interpret=True` on CPU).
Backward: the standard flash backward recomputed from the saved logsumexp,
written as a chunked pure-jnp pass (O(chunk^2) memory).  On real TPU the
backward would also be a Pallas kernel; the jnp form keeps the same HLO
FLOPs and is exact, so roofline terms and numerics are unaffected.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def flash_attention(q, k, v, causal=True, window=0, bq=128, bk=128, interpret=True):
    """q: [B, H, S, hd]; k, v: [B, Hkv, S, hd] -> [B, H, S, hd]."""
    out, _ = flash_attention_fwd(
        q, k, v, causal=causal, window=window, bq=bq, bk=bk, interpret=interpret
    )
    return out


def _fwd(q, k, v, causal, window, bq, bk, interpret):
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, bq=bq, bk=bk, interpret=interpret
    )
    return out, (q, k, v, out, lse)


def _bwd(causal, window, bq, bk, interpret, res, do):
    q, k, v, out, lse = res
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    scale = hd**-0.5
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    D = jnp.sum(dof * out.astype(jnp.float32), axis=-1)  # [B, H, S]

    c = min(bq, S)
    nq = S // c
    qs = qf.reshape(B, H, nq, c, hd)
    dos = dof.reshape(B, H, nq, c, hd)
    lses = lse.reshape(B, H, nq, c)
    Ds = D.reshape(B, H, nq, c)
    qpos_base = jnp.arange(c, dtype=jnp.int32)
    kpos = jnp.arange(S, dtype=jnp.int32)

    def q_chunk(carry, xs):
        dk, dv = carry
        qi, qb, dob, lseb, Db = xs
        qpos = qi * c + qpos_base
        s = jnp.einsum("bhqd,bhkd->bhqk", qb, kf) * scale
        mask = jnp.ones((c, S), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window > 0:
            mask &= qpos[:, None] - kpos[None, :] < window
        p = jnp.where(mask, jnp.exp(s - lseb[..., None]), 0.0)
        dv = dv + jnp.einsum("bhqk,bhqd->bhkd", p, dob)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dob, vf)
        ds = p * (dp - Db[..., None]) * scale
        dq_i = jnp.einsum("bhqk,bhkd->bhqd", ds, kf)
        dk = dk + jnp.einsum("bhqk,bhqd->bhkd", ds, qb)
        return (dk, dv), dq_i

    zeros = jnp.zeros((B, H, S, hd), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        q_chunk,
        (zeros, zeros),
        (
            jnp.arange(nq),
            qs.transpose(2, 0, 1, 3, 4),
            dos.transpose(2, 0, 1, 3, 4),
            lses.transpose(2, 0, 1, 3),
            Ds.transpose(2, 0, 1, 3),
        ),
    )
    dq = dqs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    # GQA: fold query-head grads back onto kv heads
    dk = dk.reshape(B, Hkv, G, S, hd).sum(axis=2)
    dv = dv.reshape(B, Hkv, G, S, hd).sum(axis=2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd, _bwd)


def ragged_flash_attention(
    q, k, v, lengths, *, causal=True, schedule="ws", n_programs=8,
    bq=32, bk=32, interpret=True, return_stats=False,
):
    """Ragged (variable-length) flash attention.

    ``schedule="ws"`` routes the imbalanced tile tasks through the
    device-resident fence-free work-stealing scheduler
    (:mod:`repro.pallas_ws`); ``schedule="static"`` drains the same queues
    without stealing — the static-grid baseline with identical numerics.
    """
    from repro.pallas_ws.ragged import ragged_flash_attention as _impl

    return _impl(
        q, k, v, lengths, causal=causal, schedule=schedule,
        n_programs=n_programs, bq=bq, bk=bk, interpret=interpret,
        return_stats=return_stats,
    )

"""Pure-jnp oracle for the flash attention kernel (GQA, causal, window)."""

from __future__ import annotations

import jax.numpy as jnp
import jax.nn


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, H, S, hd]; k, v: [B, Hkv, S, hd].  Materializes the full score
    matrix — oracle only, O(S^2) memory.
    """
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    kf = jnp.repeat(k, G, axis=1)
    vf = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32))
    s = s * hd**-0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)

"""Pure-jnp oracle for single-token decode attention (GQA, length-masked)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, window: int = 0):
    """q: [B, H, hd]; k, v: [B, Hkv, S, hd]; pos: scalar int32.

    Attends over slots [0, pos] (and within `window` if > 0).
    Returns [B, H, hd].
    """
    B, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), kf) * hd**-0.5
    kpos = jnp.arange(S)
    valid = kpos <= pos
    if window > 0:
        valid &= pos - kpos < window
    s = jnp.where(valid[None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vf).astype(q.dtype)

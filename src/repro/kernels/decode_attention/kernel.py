"""Single-token decode attention as a Pallas TPU kernel (split-K).

Grid: (B, H, nk) with the KV-block dim innermost/sequential; the running
online-softmax state (m, l, acc) lives in VMEM scratch across the KV sweep.
This is the flash-decoding pattern adapted to TPU: each KV block is a
[bk, hd] VMEM tile contracted on the MXU against one query row; partial
softmax states merge in registers rather than via a cross-SM reduction
(the GPU formulation) — on TPU the sequential grid IS the merge.

Blocks entirely past `pos` (or outside the sliding window) are skipped with
pl.when — decode reads only ~pos/S of the cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import compiler_params as _compiler_params

NEG_INF = -1e30


def _decode_kernel(
    pos_ref,  # scalar prefetch-style input [1] int32
    q_ref, k_ref, v_ref,  # inputs
    o_ref,  # output
    m_scr, l_scr, acc_scr,  # scratch
    *, scale: float, window: int, bk: int, nk: int,
):
    ki = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * bk
    live = k_start <= pos
    if window > 0:
        live &= pos - (k_start + bk - 1) < window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [1, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        valid = kpos <= pos
        if window > 0:
            valid &= pos - kpos < window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(
    q, k, v, pos, *, window: int = 0, bk: int = 512, interpret: bool = False
):
    """q: [B, H, hd]; k, v: [B, Hkv, S, hd]; pos scalar int32 -> [B, H, hd]."""
    B, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    bk = min(bk, S)
    nk = S // bk
    assert nk * bk == S, (S, bk)
    q4 = q[:, :, None, :]  # [B, H, 1, hd]
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, scale=hd**-0.5, window=window, bk=bk, nk=nk
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # pos scalar
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pos_arr, q4, k, v)
    return out[:, :, 0, :]

"""jit'd wrapper for the split-K decode attention kernel (inference-only:
no VJP needed — decode never backprops)."""

from __future__ import annotations

import functools

import jax

from .kernel import decode_attention as _kernel


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k, v, pos, *, window: int = 0, bk: int = 512, interpret: bool = True):
    return _kernel(q, k, v, pos, window=window, bk=bk, interpret=interpret)

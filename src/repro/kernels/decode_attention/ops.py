"""jit'd wrapper for the split-K decode attention kernel (inference-only:
no VJP needed — decode never backprops)."""

from __future__ import annotations

import functools

import jax

from .kernel import decode_attention as _kernel


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k, v, pos, *, window: int = 0, bk: int = 512, interpret: bool = True):
    return _kernel(q, k, v, pos, window=window, bk=bk, interpret=interpret)


def ragged_decode_attention(
    q, k, v, lengths, *, schedule="ws", n_programs=8, bk=64,
    interpret=True, return_stats=False,
):
    """Decode attention over ragged KV caches (per-sequence lengths).

    ``schedule="ws"`` dispatches one task per live (batch, head) through the
    fence-free work-stealing megakernel (:mod:`repro.pallas_ws`) so long
    caches don't serialize one grid program; ``schedule="static"`` is the
    no-steal baseline.
    """
    from repro.pallas_ws.ragged import ragged_decode_attention as _impl

    return _impl(
        q, k, v, lengths, schedule=schedule, n_programs=n_programs,
        bk=bk, interpret=interpret, return_stats=return_stats,
    )

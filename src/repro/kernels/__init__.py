"""repro.kernels — Pallas TPU kernels for the framework's compute hot spots.

The paper's contribution is synchronization-level (no kernel-level claims);
these kernels serve the model stack's hot spots per the mandate: fused
attention (train/prefill), SSD scan (mamba2/zamba2) and split-K decode
attention.  Each has a pure-jnp oracle in ref.py and is validated in
interpret mode on CPU; `interpret=False` targets real TPUs.
"""

from .decode_attention.ops import decode_attention
from .flash_attention.ops import flash_attention
from .ssd_scan.ops import ssd_scan

__all__ = ["decode_attention", "flash_attention", "ssd_scan"]

"""jit'd wrapper for the SSD scan kernel.

Backward: recompute via the chunked jnp formulation (models.ssm.ssd_chunked
is numerically identical); jax.vjp of that form gives exact gradients with
O(chunk^2) memory.  On real TPU the backward would be a mirrored Pallas
kernel running the recurrence in reverse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked

from .kernel import ssd_scan as _ssd_scan_kernel


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssd_scan(x, dt, A, B, C, chunk=128, interpret=True):
    """x: [b,S,H,P]; dt: [b,S,H]; A: [H]; B,C: [b,S,N] -> y [b,S,H,P]."""
    y, _ = _ssd_scan_kernel(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return y


def _fwd(x, dt, A, B, C, chunk, interpret):
    y, _ = _ssd_scan_kernel(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return y, (x, dt, A, B, C)


def _bwd(chunk, interpret, res, dy):
    x, dt, A, B, C = res
    _, vjp = jax.vjp(lambda *args: ssd_chunked(*args, chunk=chunk)[0], x, dt, A, B, C)
    return vjp(dy.astype(jnp.result_type(x)))


ssd_scan.defvjp(_fwd, _bwd)

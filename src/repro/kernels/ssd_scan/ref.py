"""Pure-jnp oracle for the SSD (Mamba-2) chunked scan kernel.

Sequential per-timestep recurrence — the ground truth the chunked forms
(models.ssm.ssd_chunked and the Pallas kernel) must match:

    S_t = exp(dt_t * A) * S_{t-1} + dt_t * x_t (outer) B_t
    y_t = C_t . S_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C):
    """x: [b, S, H, P]; dt: [b, S, H]; A: [H]; B, C: [b, S, N].

    Returns (y: [b, S, H, P], final_state: [b, H, P, N]) in float32.
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(state, ins):
        xt, dtt, Bt, Ct = ins  # [b,H,P], [b,H], [b,N], [b,N]
        decay = jnp.exp(dtt * A)  # [b,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt)
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    s0 = jnp.zeros((b, H, P, N), jnp.float32)
    final, ys = jax.lax.scan(
        step,
        s0,
        (
            xf.transpose(1, 0, 2, 3),
            dtf.transpose(1, 0, 2),
            Bf.transpose(1, 0, 2),
            Cf.transpose(1, 0, 2),
        ),
    )
    return ys.transpose(1, 0, 2, 3), final

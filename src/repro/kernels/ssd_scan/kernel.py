"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid: (B, H, n_chunks) with the chunk dim innermost and sequential; the
recurrent state [P, N] lives in VMEM scratch and is carried across chunks
(the TPU-native replacement for the GPU warp-level scan: the MXU computes
the intra-chunk quadratic term; the inter-chunk recurrence is just a rank-1
update on a resident VMEM tile).

Per (b, h, chunk) block:
  y_diag = (C B^T ∘ L) (x·dt)          — intra-chunk, lower-tri decay L
  y_off  = C S_prev^T ∘ exp(cumsum dA) — contribution of the carried state
  S     <- exp(sum dA) * S_prev + (B decay)^T (x·dt)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import compiler_params as _compiler_params


def _ssd_kernel(
    x_ref, dt_ref, A_ref, B_ref, C_ref,  # inputs
    y_ref, fin_ref,  # outputs
    state_scr,  # scratch [P, N] f32
    *, nc: int, Q: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # [Q]
    A = A_ref[0]  # scalar f32
    Bm = B_ref[0, 0].astype(jnp.float32)  # [Q, N]
    Cm = C_ref[0, 0].astype(jnp.float32)  # [Q, N]

    xdt = x * dt[:, None]
    dA = dt * A  # [Q]
    cs = jnp.cumsum(dA)

    # intra-chunk: L[i, j] = exp(cs_i - cs_j) for i >= j
    ss = cs[:, None] - cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (Q, Q), 1
    )
    L = jnp.where(tri, jnp.exp(ss), 0.0)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, Q]
    y = jax.lax.dot_general(
        scores * L, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, P]

    # carried state contribution: y_off[q] = exp(cs_q) * C_q . S_prev
    s_prev = state_scr[...]  # [P, N]
    y_off = jax.lax.dot_general(
        Cm, s_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, P]
    y = y + y_off * jnp.exp(cs)[:, None]

    # state update: S = exp(sum dA) * S_prev + sum_q decay_q * xdt_q B_q^T
    decay = jnp.exp(cs[-1] - cs)  # [Q]
    upd = jax.lax.dot_general(
        xdt * decay[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [P, N]
    state_scr[...] = jnp.exp(cs[-1]) * s_prev + upd

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _flush():
        fin_ref[0, 0] = state_scr[...]


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = False):
    """x: [b, S, H, P]; dt: [b, S, H]; A: [H] f32; B, C: [b, S, N].

    Returns (y: [b, S, H, P] in x.dtype, final_state: [b, H, P, N] f32).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert nc * Q == S, (S, Q)
    # layout: chunk-major per (b, h)
    xr = x.transpose(0, 2, 1, 3).reshape(b, H, nc, Q, P)
    dtr = dt.transpose(0, 2, 1).reshape(b, H, nc, Q)
    Br = B.reshape(b, nc, Q, N)
    Cr = C.reshape(b, nc, Q, N)
    A = A.astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, nc=nc, Q=Q)
    y, fin = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda i, h, c: (i, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1,), lambda i, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda i, h, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, h, c: (i, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda i, h, c: (i, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, h, c: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, nc, Q, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xr, dtr, A, Br, Cr)
    y = y.reshape(b, H, S, P).transpose(0, 2, 1, 3)
    return y, fin

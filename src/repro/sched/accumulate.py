"""Work-stealing gradient accumulation (the scheduler's training integration).

One global step = ``max_rounds`` lockstep rounds.  The schedule (who extracts
which microbatch task, per round) is computed by the same policy as
rounds.py, *inside the jitted step* — pure int32 ops that GSPMD replicates;
their cost is invisible next to the per-round grad computation.  The per-task
extraction counts make the multiplicity relaxation exact for SGD: an
extraction of task t contributes weight 1/count_t, so every task contributes
exactly once no matter how many workers (re)computed it.

Data movement is real: a stolen task's microbatch is gathered from the
victim's shard (``jnp.take`` over the task-sharded batch), which is exactly
"shipping the stolen task" and shows up in the dry-run collective bytes.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from .rounds import schedule_rounds


def ws_accumulate_grads(
    loss_fn: Callable[..., jnp.ndarray],
    params: Any,
    batch: Any,
    tails: jnp.ndarray,
    *,
    n_workers: int,
    mode: str = "ws-wmult",
    sync_every: int = 1,
    max_rounds: int | None = None,
    slack: int = 2,
    flat_loss: bool = False,
):
    """Accumulate gradients over one global step with work-stealing rounds.

    Args:
      loss_fn: default contract ``loss_fn(params, micro) -> [n_workers]``
        per-microbatch mean losses, with ``micro`` = batch gathered to
        [n_workers, ...].  With ``flat_loss=True`` the SPMD-friendly
        contract is used instead: ``loss_fn(params, flat_micro,
        row_weights) -> scalar`` where flat_micro leaves are
        [n_workers*rows, ...] (leading dim stays sharded over dp — no vmap,
        so GSPMD keeps the batch dim partitioned) and row_weights sum to
        the round's total task weight.
      batch: pytree whose leaves have leading dim n_tasks (global microbatch
        index, sharded over the DP axes).
      tails: [n_queues] number of tasks each worker queue owns
        (sum == n_tasks).  Data-dependent (e.g. variable-length packing).

    Returns (mean_loss, grads, aux) with aux = dict(counts, coverage, extractions).
    """
    n_tasks = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if max_rounds is None:
        base = -(-n_tasks // n_workers)  # ceil
        if mode == "ws-mult-ranked":
            max_rounds = base + slack  # exact redistribution
        elif mode == "ws-wmult-deque":
            max_rounds = max(base + slack, n_tasks // 2 + slack + 1)
        else:  # static / ws-mult / ws-wmult: head-only progress on worst skew
            max_rounds = n_tasks

    assignment, counts, _done = schedule_rounds(
        tails, n_workers, mode, sync_every, max_rounds, n_tasks
    )

    def round_body(carry, ass_r):
        grads, loss_acc, wsum = carry
        valid = ass_r >= 0
        safe = jnp.maximum(ass_r, 0)
        # 1/count weighting makes the relaxation exact for the gradient.
        w = valid.astype(jnp.float32) / jnp.maximum(counts[safe], 1)
        micro = jax.tree_util.tree_map(lambda x: x[safe], batch)

        if flat_loss:
            from repro.models.sharding import shard as _shard

            rows = jax.tree_util.tree_leaves(micro)[0].shape[1]
            flat = jax.tree_util.tree_map(
                lambda x: _shard(
                    x.reshape((-1,) + x.shape[2:]), "dp", *([None] * (x.ndim - 2))
                ),
                micro,
            )
            row_w = jnp.repeat(w, rows) / rows

            def weighted_loss(p):
                return loss_fn(p, flat, row_w)

        else:

            def weighted_loss(p):
                losses = loss_fn(p, micro)  # [n_workers]
                return (losses * w).sum()

        l, g = jax.value_and_grad(weighted_loss)(params)
        grads = jax.tree_util.tree_map(jnp.add, grads, g)
        return (grads, loss_acc + l, wsum + w.sum()), None

    zero_grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    (grads, loss_acc, wsum), _ = jax.lax.scan(
        round_body, (zero_grads, jnp.float32(0.0), jnp.float32(0.0)), assignment
    )
    denom = jnp.maximum(wsum, 1e-6)
    grads = jax.tree_util.tree_map(lambda g: g / denom, grads)
    aux = {
        "counts": counts,
        "coverage": (counts > 0).mean(),
        "extractions": counts.sum(),
        "loss_weight": wsum,
    }
    return loss_acc / denom, grads, aux

"""Asynchronous cluster simulator for the scheduler modes (makespan model).

The lockstep driver (rounds.py) is what actually runs under SPMD; this module
models the *asynchronous* regime the paper targets (host-driven dispatch,
GPU-style clusters, or TPU pods with per-host runahead): workers finish tasks
at different times and immediately pick the next one.  It quantifies the
trade the paper measures in §8:

* static    — no stealing: stragglers own their whole queue.
* ws-mult   — every pick consults the true global state, paying
              ``sync_cost`` seconds per pick (the blocking-collective /
              MaxRegister price).  No duplicates.
* ws-wmult  — every pick is free and uses a snapshot of global state that
              refreshes only every ``refresh_period`` seconds (the async
              board).  Stale snapshots can duplicate work — each worker still
              never repeats a task it did itself (local view max).
* b-ws-wmult— like ws-wmult but claims are arbitrated (Swap analogue): a
              duplicate *pick* costs a failed-claim retry of ``claim_cost``
              instead of a full duplicate execution.

Event-driven, deterministic given the seed.  Used by benchmarks/bench_scheduler.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np


@dataclass
class SimResult:
    makespan: float
    ideal: float  # total work / total speed (perfect balance, zero overhead)
    duplicates: int
    picks: int
    sync_time: float  # total seconds spent in blocking syncs

    @property
    def efficiency(self) -> float:
        return self.ideal / self.makespan if self.makespan > 0 else 0.0


def async_makespan(
    durations: np.ndarray,  # [n_tasks] seconds of work per task
    owner_of: np.ndarray,  # [n_tasks] owning worker/queue id
    n_workers: int,
    mode: str = "ws-wmult",
    worker_speed: np.ndarray | None = None,
    sync_cost: float = 5e-6,
    claim_cost: float = 1e-6,
    refresh_period: float = 1e-4,
    seed: int = 0,
) -> SimResult:
    rng = np.random.default_rng(seed)
    n_tasks = len(durations)
    speed = worker_speed if worker_speed is not None else np.ones(n_workers)
    # FIFO queues per owner
    queues = [list(np.flatnonzero(owner_of == w)) for w in range(n_workers)]
    heads_true = np.zeros(n_workers, dtype=np.int64)  # truly extracted prefix
    # per-worker local views of every queue head (weak multiplicity state)
    views = np.zeros((n_workers, n_workers), dtype=np.int64)
    board = np.zeros(n_workers, dtype=np.int64)
    board_time = 0.0
    done = np.zeros(n_tasks, dtype=bool)
    counts = np.zeros(n_tasks, dtype=np.int64)
    sync_time_total = 0.0
    picks = 0

    def snapshot(now):
        nonlocal board, board_time
        if mode == "ws-wmult" or mode == "b-ws-wmult":
            if now - board_time >= refresh_period:
                board[:] = views.max(axis=0)
                board_time = now
            return board
        return views.max(axis=0)  # fresh truth

    def pick(w, now):
        """Return (task, overhead_seconds) or (None, overhead)."""
        nonlocal picks, sync_time_total
        overhead = 0.0
        if mode == "ws-mult":
            overhead += sync_cost
            sync_time_total += sync_cost
            views[w] = np.maximum(views[w], views.max(axis=0))
        elif mode in ("ws-wmult", "b-ws-wmult"):
            views[w] = np.maximum(views[w], snapshot(now))
        # own queue first, else richest victim (by my view)
        order = [w] + [q for q in range(n_workers) if q != w]
        remaining = np.array([len(queues[q]) - views[w][q] for q in range(n_workers)])
        if mode == "static":
            cands = [w] if remaining[w] > 0 else []
        else:
            cands = [w] if remaining[w] > 0 else (
                [int(np.argmax(np.where(np.arange(n_workers) != w, remaining, -1)))]
                if remaining.max(initial=0) > 0
                else []
            )
        for q in cands:
            if len(queues[q]) - views[w][q] <= 0:
                continue
            t = queues[q][views[w][q]]
            views[w][q] += 1
            picks += 1
            if mode == "ws-mult":
                # fresh truth + per-pick arbitration: exact, no duplicates
                if done[t]:
                    continue
                return t, overhead
            if mode == "b-ws-wmult" and done[t]:
                # Swap claim fails: pay retry, skip the stale task
                overhead += claim_cost
                continue
            return t, overhead
        return None, overhead

    # event loop: (time, worker)
    events = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(events)
    finish = 0.0
    idle_until = {}
    POLL = refresh_period if refresh_period > 0 else 1e-4
    while events:
        now, w = heapq.heappop(events)
        if done.all():
            break
        t, overhead = pick(w, now)
        if t is None:
            # idle: poll again shortly (models backoff)
            if not done.all():
                heapq.heappush(events, (now + POLL, w))
            continue
        dur = durations[t] / speed[w] + overhead
        counts[t] += 1
        done[t] = True
        finish = max(finish, now + dur)
        heapq.heappush(events, (now + dur, w))

    duplicates = int(counts.sum() - (counts > 0).sum())
    ideal = float(durations.sum() / speed.sum())
    return SimResult(
        makespan=finish,
        ideal=ideal,
        duplicates=duplicates,
        picks=picks,
        sync_time=sync_time_total,
    )

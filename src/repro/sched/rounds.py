"""Lockstep round driver: run Take/Steal rounds for all workers under a mode.

This is the SPMD execution model of the scheduler (DESIGN.md §2): one *round*
= every worker extracts ≤ 1 microbatch task and processes it; rounds proceed
in lockstep (that is what a single jitted program gives you).

The views matrix [n_workers, n_queues] carries every worker's
RangeMaxRegister state: ``views[w, q]`` is worker w's persistent local lower
bound on queue q's head (its local ``r``).  The paper's shared register ``R``
maps to the *board*: an all-reduce(max) of the views whose result is consumed
``one round later`` — i.e. an **async collective that never blocks the
critical path**.  Reading the board is exactly RMaxRead: ``max(local r, stale
R)``, a valid lower bound that always includes the worker's own extractions,
so no worker ever re-extracts a task it extracted (weak multiplicity), while
cross-worker staleness can duplicate work — boundedly and countedly.

Modes:

* static         — no stealing: a worker only drains its own queue; no board.
* ws-mult        — blocking MaxRegister semantics, paper-faithful: views are
                   pmax-unified every round and same-head contention is
                   arbitrated by a claim min-reduce (the B-WS Swap analogue).
                   A *synchronous* collective per round; zero duplicate
                   compute; thieves that lose a claim idle that round.
* ws-mult-ranked — beyond-paper exact mode: the synced view lets every
                   stealer deterministically take a distinct steal slot
                   (pick_ranked) — no claims, no idle-by-collision.  Still one
                   blocking collective per round.
* ws-wmult       — collective-free fast path: picks use only local views
                   merged with the stale async board (refreshed every
                   ``sync_every`` rounds, consumed the following round).
                   Victims are salt-randomized to decorrelate thieves.
                   Duplicates possible-but-counted.
* ws-wmult-deque — collective-free AND net-progress in lockstep: owners drain
                   their queue from the HEAD, thieves steal from the TAIL
                   behind a per-queue *reverse watermark* (monotonically
                   decreasing; published on the async board with min-merge).
                   The two frontiers meet in the middle; staleness only
                   duplicates the crossover region, never loses a task.  This
                   is the paper's §9 "other insert/extract orders" direction:
                   the FIFO head-only queue admits ≤1 net extraction per queue
                   per round in BSP no matter how many thieves (head
                   contention IS multiplicity), so lockstep redistribution
                   needs either a synced view (ws-mult-ranked) or opposite-end
                   extraction (this mode).

Returns the per-round assignment (for gradient accumulation), per-task
extraction counts, and scheduling statistics (rounds used, duplicate ratio,
blocking/async collectives issued) — the quantities tabulated in
EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from .policy import _hash, pick_ranked, pick_tasks, queue_bases, resolve_claims, sync_views

MODES = ("static", "ws-mult", "ws-mult-ranked", "ws-wmult", "ws-wmult-deque")


@dataclass
class RoundStats:
    rounds_used: int
    total_picks: int
    duplicate_picks: int
    idle_worker_rounds: int
    blocking_collectives: int
    async_collectives: int

    @property
    def duplicate_ratio(self) -> float:
        return self.duplicate_picks / max(self.total_picks, 1)


def schedule_rounds(
    tails: jnp.ndarray,
    n_workers: int,
    mode: str,
    sync_every: int,
    max_rounds: int,
    n_tasks: int,
):
    """Traced schedule computation shared by the driver and by train steps.

    Returns (assignment [R, n_w] int32 task-or--1, counts [n_tasks] int32,
    done_round int32: first round after which every task was extracted, or -1).
    """
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not in {MODES}")
    if mode == "ws-wmult-deque":
        return _schedule_deque(tails, n_workers, sync_every, max_rounds, n_tasks)
    n_q = tails.shape[0]
    worker_ids = jnp.arange(n_workers, dtype=jnp.int32)

    def pick_one(view, wid, r):
        if mode == "static":
            have = tails[wid] - view[wid] > 0
            task = jnp.where(have, queue_bases(tails)[wid] + view[wid], -1)
            new_view = jnp.where(have, view.at[wid].add(1), view)
            return task, new_view
        if mode == "ws-mult-ranked":
            return pick_ranked(view, tails, wid, n_workers)
        task, _q, new_view = pick_tasks(
            view, tails, wid, salt=r, victim_policy="random"
        )
        return task, new_view

    def body(carry, r):
        views, board, counts, done_round = carry
        if mode == "ws-wmult":
            # RMaxRead: merge the stale async board into the local view.
            views = jnp.maximum(views, board[None, :])
        tasks, new_views = jax.vmap(pick_one, in_axes=(0, 0, None))(
            views, worker_ids, r
        )

        if mode == "ws-mult":
            won = resolve_claims(tasks, worker_ids, n_tasks, axis_name=None)
            eff = jnp.where(won, tasks, -1)
            new_views = sync_views(new_views)  # blocking MaxRegister publish
        elif mode == "ws-mult-ranked":
            eff = tasks
            new_views = sync_views(new_views)
        else:
            eff = tasks
            if mode == "ws-wmult":
                refresh = (r % jnp.maximum(sync_every, 1)) == 0
                board = jnp.where(refresh, new_views.max(axis=0), board)

        valid = eff >= 0
        counts = counts.at[jnp.maximum(eff, 0)].add(valid.astype(jnp.int32))
        all_done = (counts > 0).all()
        done_round = jnp.where((done_round < 0) & all_done, r + 1, done_round)
        return (new_views, board, counts, done_round), eff

    views0 = jnp.zeros((n_workers, n_q), dtype=jnp.int32)
    board0 = jnp.zeros((n_q,), dtype=jnp.int32)
    counts0 = jnp.zeros((n_tasks,), dtype=jnp.int32)
    (_, _, counts, done_round), assignment = jax.lax.scan(
        body, (views0, board0, counts0, jnp.int32(-1)), jnp.arange(max_rounds)
    )
    return assignment, counts, done_round


def _schedule_deque(tails, n_workers, sync_every, max_rounds, n_tasks):
    """ws-wmult-deque scheduling (see module docstring).

    Per-worker state: ``heads[w, q]`` (forward frontier view, max-merged
    board) and ``rwms[w, q]`` (reverse watermark view, min-merged board).
    Queue q has unextracted-by-someone slots in [true_head, true_rwm); a
    worker believes slots remain while ``heads[w,q] < rwms[w,q]``.
    """
    n_q = tails.shape[0]
    worker_ids = jnp.arange(n_workers, dtype=jnp.int32)
    bases = queue_bases(tails)

    def pick_one(head_v, rwm_v, wid, r):
        remaining = jnp.maximum(rwm_v - head_v, 0)
        have_own = remaining[wid] > 0
        own_task = bases[wid] + head_v[wid]

        qids = jnp.arange(n_q)
        eligible = (qids != wid) & (remaining > 0)
        score = _hash(
            qids.astype(jnp.uint32)
            + _hash(jnp.uint32(wid) * jnp.uint32(2654435761))
            + jnp.uint32(r) * jnp.uint32(40503)
        ).astype(jnp.int32) & jnp.int32(0x7FFFFFFF)
        score = jnp.where(eligible, score, -1)
        victim = jnp.argmax(score)
        can_steal = eligible[victim]

        steal_task = bases[victim] + rwm_v[victim] - 1
        task = jnp.where(have_own, own_task, jnp.where(can_steal, steal_task, -1))
        new_head = jnp.where(have_own, head_v.at[wid].add(1), head_v)
        new_rwm = jnp.where(
            have_own, rwm_v, jnp.where(can_steal, rwm_v.at[victim].add(-1), rwm_v)
        )
        return task, new_head, new_rwm

    def body(carry, r):
        heads, rwms, b_head, b_rwm, counts, done_round = carry
        # RMaxRead / reverse: merge the stale async boards
        heads = jnp.maximum(heads, b_head[None, :])
        rwms = jnp.minimum(rwms, b_rwm[None, :])
        tasks, new_heads, new_rwms = jax.vmap(
            pick_one, in_axes=(0, 0, 0, None)
        )(heads, rwms, worker_ids, r)

        refresh = (r % jnp.maximum(sync_every, 1)) == 0
        b_head = jnp.where(refresh, new_heads.max(axis=0), b_head)
        b_rwm = jnp.where(refresh, new_rwms.min(axis=0), b_rwm)

        valid = tasks >= 0
        counts = counts.at[jnp.maximum(tasks, 0)].add(valid.astype(jnp.int32))
        all_done = (counts > 0).all()
        done_round = jnp.where((done_round < 0) & all_done, r + 1, done_round)
        return (new_heads, new_rwms, b_head, b_rwm, counts, done_round), tasks

    heads0 = jnp.zeros((n_workers, n_q), dtype=jnp.int32)
    rwms0 = jnp.broadcast_to(tails[None, :], (n_workers, n_q)).astype(jnp.int32)
    counts0 = jnp.zeros((n_tasks,), dtype=jnp.int32)
    (_, _, _, _, counts, done_round), assignment = jax.lax.scan(
        body,
        (heads0, rwms0, heads0[0], rwms0[0], counts0, jnp.int32(-1)),
        jnp.arange(max_rounds),
    )
    return assignment, counts, done_round


@partial(jax.jit, static_argnames=("n_workers", "mode", "sync_every", "max_rounds", "n_tasks"))
def _run(tails, n_workers, mode, sync_every, max_rounds, n_tasks):
    return schedule_rounds(tails, n_workers, mode, sync_every, max_rounds, n_tasks)


def run_lockstep_rounds(
    tails,
    n_workers: int,
    mode: str = "ws-wmult",
    sync_every: int = 1,
    max_rounds: int | None = None,
):
    """Run the scheduler; returns (assignment [R, n_w], counts, RoundStats).

    ``counts[t]`` is how many workers extracted task t; the done-condition is
    every task extracted at least once (the paper's at-least-once guarantee).
    """
    tails = jnp.asarray(tails, dtype=jnp.int32)
    n_tasks = int(tails.sum())
    if max_rounds is None:
        max_rounds = int(tails.max()) if mode == "static" else n_tasks
        max_rounds = max(max_rounds, 1)
    assignment, counts, done_round = _run(
        tails, n_workers, mode, sync_every, max_rounds, n_tasks
    )
    assignment = jax.device_get(assignment)
    counts = jax.device_get(counts)
    rounds_used = int(done_round) if int(done_round) >= 0 else max_rounds
    total_picks = int((assignment[:rounds_used] >= 0).sum())
    dup = int(total_picks - (counts > 0).sum())
    idle = int(rounds_used * n_workers - total_picks)
    blocking = rounds_used if mode in ("ws-mult", "ws-mult-ranked") else 0
    async_c = 0
    if mode in ("ws-wmult", "ws-wmult-deque"):
        async_c = max(1, rounds_used // max(sync_every, 1))
    stats = RoundStats(
        rounds_used=rounds_used,
        total_picks=total_picks,
        duplicate_picks=dup,
        idle_worker_rounds=idle,
        blocking_collectives=blocking,
        async_collectives=async_c,
    )
    return assignment[:rounds_used], counts, stats

"""Pure-jnp scheduler policy: Take/Steal picks over per-worker head views.

State layout (everything int32):

* ``tails[n_q]``  — per-queue tail (number of tasks the owner Put).  Tasks are
  microbatch indices; queue q owns the global index range
  [bases[q], bases[q] + tails[q]) with ``bases = cumsum(tails) - tails``.
  Puts are a local owner action (the paper's O(1) fence-free Put) and happen
  at step assembly; during the rounds tails are constant.
* ``view[n_q]``   — ONE worker's local lower bounds on every queue's head.
  This is exactly the paper's RangeMaxRegister state vector: ``view[q]`` is
  worker w's persistent local ``r`` for queue q's Head, and the true head is
  ``max_w view_w[q]``.

Pick rules (FIFO head extraction, per the paper's insert/extract order):

* ``pick_tasks``  — Take from the own queue if non-empty in the view, else
  Steal the *head* of a victim queue.  ``victim_policy``:
  - 'richest': most remaining in view (tie → lower qid);
  - 'random' : salted hash over eligible victims — decorrelates thieves
    between workers/rounds without any communication.
  Thieves contending on the same head is precisely the paper's multiplicity;
  in ws-wmult mode such picks duplicate (bounded, counted), in claim modes
  they are arbitrated.

* ``pick_ranked`` — beyond-paper exact mode (requires views synced first):
  every worker deterministically computes this round's full steal allocation
  from the shared view — stealers are ranked by id and take distinct
  depth-major slots across victim queues.  Zero collisions, zero idle-by-
  collision, no claim collective needed.  Only sound when views are unified
  (fresh MaxRegister read); with stale views it could mark skipped tasks as
  done, so ws-wmult must NOT use it.

All functions are shape-polymorphic pure jnp so they run identically inside
``shard_map`` (per-device ``view`` rows, ``jax.lax.pmax`` for sync) and in the
vmapped lockstep simulator (``view`` matrix, axis-0 max for sync).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def queue_bases(tails: jnp.ndarray) -> jnp.ndarray:
    """Global task-index base of each queue."""
    return jnp.cumsum(tails) - tails


def _hash(x: jnp.ndarray) -> jnp.ndarray:
    """Cheap int32 mixing (xorshift-multiply) for salted victim selection."""
    x = jnp.uint32(x) if not isinstance(x, jnp.ndarray) else x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def pick_tasks(
    view: jnp.ndarray,
    tails: jnp.ndarray,
    worker_id: jnp.ndarray,
    salt: jnp.ndarray | int = 0,
    victim_policy: str = "richest",
):
    """One worker's Take-else-Steal pick (head extraction only).

    Returns (task, queue, new_view): ``task`` is the global task index or -1;
    ``queue`` the queue it came from (or -1); ``new_view`` the view with that
    queue's local head advanced (the paper's local ``head <- head+1``).
    """
    n_q = tails.shape[0]
    remaining = jnp.maximum(tails - view, 0)
    bases = queue_bases(tails)

    have_own = remaining[worker_id] > 0

    qids = jnp.arange(n_q)
    eligible = (qids != worker_id) & (remaining > 0)
    if victim_policy == "random":
        salt_v = jnp.asarray(salt, dtype=jnp.int32)
        score = _hash(
            qids.astype(jnp.uint32)
            + _hash(jnp.uint32(worker_id) * jnp.uint32(2654435761))
            + jnp.uint32(salt_v) * jnp.uint32(40503)
        ).astype(jnp.int32) & jnp.int32(0x7FFFFFFF)
        score = jnp.where(eligible, score, -1)
    else:
        score = jnp.where(eligible, remaining, -1)
    victim = jnp.argmax(score)
    can_steal = score[victim] > 0 if victim_policy == "richest" else eligible[victim]

    queue = jnp.where(have_own, worker_id, jnp.where(can_steal, victim, -1))
    got = queue >= 0
    safe_q = jnp.maximum(queue, 0)
    task = jnp.where(got, bases[safe_q] + view[safe_q], -1)
    new_view = jnp.where(got, view.at[safe_q].add(1), view)
    return task, queue, new_view


def pick_ranked(view: jnp.ndarray, tails: jnp.ndarray, worker_id: jnp.ndarray,
                n_workers: int):
    """Deterministic collision-free pick from a *synced* view (see module doc).

    Own-queue owners take their head.  Stealers (workers whose own queue is
    empty in the shared view) are ranked by id; steal slots are the non-head
    remainder of every non-empty queue, enumerated depth-major (all depth-1
    slots by qid, then depth-2, ...), and stealer #r takes slot #r.  Depth is
    bounded by the number of stealers < n_workers.
    """
    n_q = tails.shape[0]
    remaining = jnp.maximum(tails - view, 0)
    bases = queue_bases(tails)

    have_own = remaining[worker_id] > 0
    own_task = bases[worker_id] + view[worker_id]

    # my rank among stealers (workers are queue owners: n_workers == n_q)
    is_stealer = remaining[:n_workers] == 0
    rank = jnp.cumsum(is_stealer.astype(jnp.int32))[worker_id] - 1

    # steal slots per queue: everything behind the head (the owner takes the head)
    steal_cnt = jnp.maximum(remaining - 1, 0)
    # depth-major enumeration: level d has count_d = #{q : steal_cnt[q] > d}
    depths = jnp.arange(n_workers)[:, None]  # max useful depth < n_workers
    level_cnt = (steal_cnt[None, :] > depths).sum(axis=1)  # [n_workers]
    cum = jnp.cumsum(level_cnt) - level_cnt  # slots before level d
    total_slots = level_cnt.sum()

    # my slot: level d* = last level with cum <= rank; position p within it
    d_star = jnp.sum((cum <= rank) & (level_cnt > 0)) - 1
    d_star = jnp.maximum(d_star, 0)
    p = rank - cum[d_star]
    in_level = steal_cnt > d_star
    pos_in_level = jnp.cumsum(in_level.astype(jnp.int32)) - 1
    q_star = jnp.argmax(in_level & (pos_in_level == p))

    can_steal = (~have_own) & (rank >= 0) & (rank < total_slots)
    offset = view[q_star] + 1 + d_star
    steal_task = bases[q_star] + offset

    task = jnp.where(have_own, own_task, jnp.where(can_steal, steal_task, -1))
    # local head bounds: owner head+1; stealer knows [head, offset] all extract
    # this round (lower ranks fill shallower depths), so it may advance to
    # offset+1 — sound ONLY because the view is shared/synced.
    new_view = jnp.where(
        have_own,
        view.at[worker_id].add(1),
        jnp.where(can_steal, view.at[q_star].set(offset + 1), view),
    )
    return task, new_view


def sync_views(views: jnp.ndarray, axis_name: str | None = None) -> jnp.ndarray:
    """MaxRegister read: the true head is the max over workers' local views.

    Inside shard_map pass ``axis_name`` (per-device row + pmax); in the
    simulator pass the [n_w, n_q] matrix (axis-0 max, broadcast back).
    """
    if axis_name is not None:
        return jax.lax.pmax(views, axis_name)
    m = views.max(axis=0, keepdims=True)
    return jnp.broadcast_to(m, views.shape)


def resolve_claims(
    tasks: jnp.ndarray,
    worker_ids: jnp.ndarray,
    n_tasks: int,
    axis_name: str | None = None,
):
    """B-WS-style claim resolution: at most one worker wins each task.

    The paper's Swap becomes a deterministic min-reduce: every worker writes
    (its id) into its picked task's slot; the all-reduce(min) elects the
    lowest id.  Returns a bool per worker: did *my* claim win?

    ``tasks``: per-worker picked task (or -1).  In shard_map form, ``tasks``
    is a scalar per device and the claim table rides one tiny collective.
    """
    big = jnp.int32(2**30)
    if axis_name is not None:
        # per-device scalar task -> one-hot claim row, pmin over devices
        claim = jnp.full((n_tasks,), big, dtype=jnp.int32)
        safe_t = jnp.maximum(tasks, 0)
        claim = jnp.where(
            (jnp.arange(n_tasks) == safe_t) & (tasks >= 0), worker_ids, claim
        )
        table = jax.lax.pmin(claim, axis_name)
        won = (tasks >= 0) & (table[jnp.maximum(tasks, 0)] == worker_ids)
        return won
    # simulator form: tasks [n_w], worker_ids [n_w]
    claim = jnp.full((n_tasks,), big, dtype=jnp.int32)
    safe_t = jnp.maximum(tasks, 0)
    claim = claim.at[safe_t].min(jnp.where(tasks >= 0, worker_ids, big))
    won = (tasks >= 0) & (claim[safe_t] == worker_ids)
    return won

"""repro.sched — the paper's work-stealing adapted to SPMD TPU training.

Mapping (see DESIGN.md §2): per-worker microbatch FIFO queues; the queue-head
MaxRegister becomes an all-reduce(max) over per-worker head views, and the
RangeMaxRegister becomes each worker's *stale local* view — eliding the
collective entirely on the fast path.  Modes:

* ``static``    — no stealing (baseline).
* ``ws-mult``   — fresh global head view + claim resolution every round
                  (per-round tiny collective; zero duplicate compute) — the
                  WS-MULT / B-WS analogue where the MaxRegister is consulted
                  per operation.
* ``ws-wmult``  — collective-free rounds on stale local views; duplicates are
                  possible but (a) bounded — a worker never re-extracts a task
                  it extracted (weak multiplicity), and (b) *counted*, so the
                  gradient normalization stays correct.
* ``sync_every=k`` interpolates (periodic RangeMaxRegister refresh).
"""

from .policy import pick_ranked, pick_tasks, resolve_claims, sync_views
from .rounds import MODES, RoundStats, run_lockstep_rounds, schedule_rounds
from .simulator import async_makespan
from .accumulate import ws_accumulate_grads

__all__ = [
    "MODES",
    "RoundStats",
    "async_makespan",
    "pick_ranked",
    "pick_tasks",
    "resolve_claims",
    "run_lockstep_rounds",
    "schedule_rounds",
    "sync_views",
    "ws_accumulate_grads",
]
